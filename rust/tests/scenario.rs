//! Scenario-engine tests: the closed-form identity on static specs, the
//! bitwise shard-count independence of the fleet runner, chunked-epoch
//! exactness, churn/mobility bookkeeping and TOML end-to-end.

use hfl::assoc;
use hfl::config::AssocStrategy;
use hfl::delay::DelayInstance;
use hfl::net::{Channel, SystemParams, Topology};
use hfl::opt::{solve_integer, SolveOptions};
use hfl::scenario::{
    run_batch, run_batch_traced, run_instance, run_instance_traced, BatchReport, ResolveMode,
    ScenarioOutcome, ScenarioSpec,
};
use hfl::trace::{strip_walls, Counter, JsonlSink, Phase, StatsSink, TraceProfile, TraceSink};
use hfl::util::proptest::check;

fn rel_close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * b.abs().max(1.0)
}

/// Independently rebuild the paper pipeline for a static spec and return
/// the closed-form makespan `⌈R⌉ · T(a*, b*)` plus (a*, b*).
fn closed_form_reference(spec: &ScenarioSpec, seed: u64) -> (f64, u64, u64) {
    let base = &spec.base;
    let topo = Topology::sample(&base.system, base.num_edges, base.num_ues, seed);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let cap = base.system.edge_capacity();
    let association = match base.assoc {
        AssocStrategy::Proposed => assoc::time_minimized(&channel, cap).unwrap(),
        AssocStrategy::Greedy => assoc::greedy(&channel, cap).unwrap(),
        other => panic!("reference pipeline does not cover {other:?}"),
    };
    let inst = DelayInstance::build(&topo, &channel, &association, base.eps);
    let sol = solve_integer(&inst, &SolveOptions::default());
    (
        inst.total_time_int(sol.a as f64, sol.b as f64),
        sol.a,
        sol.b,
    )
}

#[test]
fn static_spec_reproduces_closed_form() {
    let spec = ScenarioSpec::new().edges(3).ues(30).eps(0.25).seed(7);
    let out = run_instance(&spec, 1234).unwrap();
    let (expect, a, b) = closed_form_reference(&spec, 1234);
    assert_eq!((out.a, out.b), (a, b), "same optimizer solution");
    assert_eq!(out.epochs, 1, "static spec runs in one epoch");
    assert!(out.converged);
    assert_eq!(
        out.closed_form_s.to_bits(),
        expect.to_bits(),
        "engine's closed form must be the paper's R_int * T"
    );
    assert!(
        rel_close(out.makespan_s, expect, 1e-9),
        "simulated {} vs closed form {expect}",
        out.makespan_s
    );
}

#[test]
fn prop_static_specs_match_closed_form() {
    check("scenario static == R_int * T", 24, |rng| {
        let edges = rng.int_range(2, 5) as usize;
        let cap_each = rng.int_range(5, 20) as usize;
        let max_ues = (edges * cap_each) as i64;
        let ues = rng.int_range(edges as i64, (max_ues * 4 / 5).max(edges as i64)) as usize;
        let mut params = SystemParams::default();
        params.ue_bandwidth_hz = params.edge_bandwidth_hz / cap_each as f64;
        let strategy = if rng.f64() < 0.5 {
            AssocStrategy::Proposed
        } else {
            AssocStrategy::Greedy
        };
        let mut spec = ScenarioSpec::new()
            .edges(edges)
            .ues(ues)
            .eps(rng.range(0.05, 0.5))
            .assoc(strategy);
        spec.base.system = params;
        let seed = rng.next_u64();
        let out = run_instance(&spec, seed).unwrap();
        let (expect, a, b) = closed_form_reference(&spec, seed);
        assert_eq!((out.a, out.b), (a, b));
        assert_eq!(out.closed_form_s.to_bits(), expect.to_bits());
        assert!(
            rel_close(out.makespan_s, expect, 1e-9),
            "sim {} vs closed {expect}",
            out.makespan_s
        );
    });
}

#[test]
fn chunked_epochs_accrue_bit_exactly() {
    // Zero-dynamics + zero-failure: splitting the run into 1-round epochs
    // (re-associating and re-solving between every round) must reproduce
    // the single-epoch makespan bit for bit.
    let whole_spec = ScenarioSpec::new().edges(2).ues(20).eps(0.1).seed(3);
    let chunked_spec = whole_spec.clone().epoch_rounds(1).max_epochs(100_000);
    let whole = run_instance(&whole_spec, 99).unwrap();
    let chunked = run_instance(&chunked_spec, 99).unwrap();
    assert_eq!(whole.rounds, chunked.rounds);
    assert_eq!(chunked.epochs, whole.rounds, "one epoch per round");
    assert!(whole.converged && chunked.converged);
    // The simulated clock advances through the identical per-round addition
    // sequence either way — bitwise equal. The closed-form bookkeeping is
    // R·T in one multiply vs a per-epoch sum of T, so only near-equal.
    assert_eq!(whole.makespan_s.to_bits(), chunked.makespan_s.to_bits());
    assert!(rel_close(whole.closed_form_s, chunked.closed_form_s, 1e-12));
}

fn dynamic_spec() -> ScenarioSpec {
    ScenarioSpec::new()
        .edges(3)
        .ues(40)
        .eps(0.1)
        .seed(11)
        .mobility(1.0, 5.0)
        .churn(1.0, 0.1)
        .jitter(0.1)
        .dropout(0.05)
        .epoch_rounds(1)
        .max_epochs(64)
}

fn assert_outcomes_bitwise_equal(a: &[ScenarioOutcome], b: &[ScenarioOutcome]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.instance, y.instance);
        assert_eq!(x.seed, y.seed);
        assert_eq!(x.makespan_s.to_bits(), y.makespan_s.to_bits());
        assert_eq!(x.closed_form_s.to_bits(), y.closed_form_s.to_bits());
        assert_eq!(x.rounds, y.rounds);
        assert_eq!(x.epochs, y.epochs);
        assert_eq!(x.converged, y.converged);
        assert_eq!((x.a, x.b), (y.a, y.b));
        assert_eq!(x.handovers, y.handovers);
        assert_eq!(x.arrivals, y.arrivals);
        assert_eq!(x.departures, y.departures);
        assert_eq!(x.dropped_uploads, y.dropped_uploads);
        assert_eq!(x.late_uploads, y.late_uploads);
        assert_eq!(x.scheduled_uploads, y.scheduled_uploads);
        assert_eq!(x.participation_rate.to_bits(), y.participation_rate.to_bits());
        assert_eq!(x.outages, y.outages);
        assert_eq!(x.recoveries, y.recoveries);
        assert_eq!(x.down_edge_epochs, y.down_edge_epochs);
        assert_eq!(x.events, y.events);
        assert_eq!(x.ue_barrier_wait_s.to_bits(), y.ue_barrier_wait_s.to_bits());
        assert_eq!(
            x.edge_barrier_wait_s.to_bits(),
            y.edge_barrier_wait_s.to_bits()
        );
        // Re-solve and re-association bookkeeping is deterministic too —
        // all but the measured wall times (resolve_time_s/assoc_time_s).
        assert_eq!(x.ab_per_epoch, y.ab_per_epoch);
        assert_eq!(x.resolves, y.resolves);
        assert_eq!(x.cold_resolves, y.cold_resolves);
        assert_eq!(x.reassociations, y.reassociations);
        // Trace counters are part of the trajectory; wall_s spans are
        // measured and exempt.
        assert_eq!(x.phase.counters, y.phase.counters);
    }
}

#[test]
fn certify_is_a_pure_reporting_knob() {
    // `certify = true` attaches the flow-bound certificate without
    // perturbing anything else: every trajectory field is
    // bitwise-identical to the certify-off run (the knob consumes no
    // RNG), and the certificate itself is sound on every instance of a
    // dynamic world with churn, mobility AND outages in play.
    let spec = dynamic_spec().outage(0.2, 0.5).instances(8);
    let off = run_batch(&spec.clone()).unwrap();
    let on = run_batch(&spec.clone().certify(true)).unwrap();
    // The helper compares every field except the certificate columns.
    assert_outcomes_bitwise_equal(&off.outcomes, &on.outcomes);
    for o in &off.outcomes {
        assert_eq!(o.assoc_lower_bound, 0.0, "certify off must report 0.0");
        assert_eq!(o.assoc_gap, 0.0);
    }
    for o in &on.outcomes {
        assert!(
            o.assoc_lower_bound.is_finite() && o.assoc_lower_bound >= 0.0,
            "instance {}: bound {}",
            o.instance,
            o.assoc_lower_bound
        );
        assert!(
            o.assoc_gap >= 0.0,
            "instance {}: negative gap {} (bound above achieved)",
            o.instance,
            o.assoc_gap
        );
    }
    // Populated worlds certify non-trivially (a zero bound would mean
    // every epoch ended empty or uncertifiable).
    assert!(
        on.outcomes.iter().any(|o| o.assoc_lower_bound > 0.0),
        "at least one instance must carry a positive bound"
    );
    // And the batch report surfaces the new columns.
    let report = BatchReport::from_outcomes(&on.outcomes);
    assert!(report.assoc_lower_bound.max > 0.0);
    assert!(report.assoc_gap.min >= 0.0);
    let json = report.to_json(None).to_string();
    assert!(json.contains("\"assoc_lower_bound\"") && json.contains("\"assoc_gap\""));
}

#[test]
fn runner_is_bitwise_deterministic_across_shard_counts() {
    let spec = dynamic_spec().instances(12);
    let one = run_batch(&spec.clone().shards(1)).unwrap();
    let eight = run_batch(&spec.clone().shards(8)).unwrap();
    assert_eq!(one.shards, 1);
    assert_outcomes_bitwise_equal(&one.outcomes, &eight.outcomes);
    // And re-running the same sharded batch reproduces itself.
    let eight_again = run_batch(&spec.clone().shards(8)).unwrap();
    assert_outcomes_bitwise_equal(&eight.outcomes, &eight_again.outcomes);
}

#[test]
fn dynamic_instance_is_deterministic_and_does_dynamics() {
    let spec = dynamic_spec();
    let a = run_instance(&spec, 77).unwrap();
    let b = run_instance(&spec, 77).unwrap();
    assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
    assert_eq!(a.handovers, b.handovers);
    assert!(a.epochs > 1, "dynamic run must span multiple epochs");
    assert!(a.rounds >= 1);
    assert!(a.events > 0);
    assert!(a.makespan_s > 0.0);
    // 40 UEs at 10% departure across several epochs: a departure-free run
    // is astronomically unlikely for any seed.
    assert!(a.departures > 0, "churn must fire");
    // Dropout at 5% across hundreds of UE-round uploads.
    assert!(a.dropped_uploads > 0, "dropout must fire");
    // The incremental association engine scored the full fleet at least
    // once (the first epoch) and its bookkeeping is deterministic.
    assert!(a.reassociations >= 40, "first epoch scores everyone");
    assert_eq!(a.reassociations, b.reassociations);
}

#[test]
fn warm_assoc_reproduces_cold_trajectory() {
    // The incremental association engine must hand the epoch loop maps
    // bitwise-identical to the from-scratch policy runs, for every
    // strategy, so warm and cold runs share one trajectory.
    for strategy in [
        AssocStrategy::Proposed,
        AssocStrategy::Greedy,
        AssocStrategy::Random,
    ] {
        for seed in [5u64, 31] {
            let warm = run_instance(
                &dynamic_spec().assoc(strategy).assoc_resolve(ResolveMode::Warm),
                seed,
            )
            .unwrap();
            let cold = run_instance(
                &dynamic_spec().assoc(strategy).assoc_resolve(ResolveMode::Cold),
                seed,
            )
            .unwrap();
            assert_eq!(warm.ab_per_epoch, cold.ab_per_epoch, "{strategy:?} seed {seed}");
            assert_eq!(warm.makespan_s.to_bits(), cold.makespan_s.to_bits());
            assert_eq!(warm.closed_form_s.to_bits(), cold.closed_form_s.to_bits());
            assert_eq!(warm.handovers, cold.handovers);
            assert_eq!(warm.rounds, cold.rounds);
            assert_eq!(warm.epochs, cold.epochs);
        }
    }
    // The latency-keyed exact policy re-runs cold inside the warm engine;
    // the trajectories still agree bit for bit.
    let spec = ScenarioSpec::new()
        .edges(2)
        .ues(12)
        .eps(0.25)
        .mobility(1.0, 3.0)
        .churn(0.5, 0.05)
        .epoch_rounds(1)
        .max_epochs(16)
        .assoc(AssocStrategy::Exact);
    let warm = run_instance(&spec.clone().assoc_resolve(ResolveMode::Warm), 9).unwrap();
    let cold = run_instance(&spec.assoc_resolve(ResolveMode::Cold), 9).unwrap();
    assert_eq!(warm.ab_per_epoch, cold.ab_per_epoch);
    assert_eq!(warm.makespan_s.to_bits(), cold.makespan_s.to_bits());
}

#[test]
fn total_departure_drains_to_zero_time_rounds() {
    // Every UE leaves after the first epoch and nobody returns: the run
    // must still converge, and the memberless rounds take no time (the
    // emptied edges have nothing to aggregate or upload).
    let spec = ScenarioSpec::new()
        .edges(2)
        .ues(10)
        .eps(0.25)
        .seed(5)
        .churn(0.0, 1.0)
        .epoch_rounds(1)
        .max_epochs(200);
    let out = run_instance(&spec, 21).unwrap();
    assert_eq!(out.departures, 10);
    assert!(out.converged, "drained protocol still terminates");
    assert!(out.makespan_s.is_finite());
}

#[test]
fn emptied_edges_stop_contributing_backhaul() {
    // Regression for the post-churn delay-model bug: an edge emptied by
    // departures kept injecting `b·0 + backhaul_s` into T(a,b). Here the
    // whole fleet departs after epoch 1, so the fixed makespan is exactly
    // the single live round; pre-fix every remaining round added the max
    // backhaul, inflating the makespan ~rounds-fold.
    let spec = ScenarioSpec::new()
        .edges(2)
        .ues(10)
        .eps(0.25)
        .seed(5)
        .assoc(AssocStrategy::Greedy)
        .churn(0.0, 1.0)
        .epoch_rounds(1)
        .max_epochs(200);
    let out = run_instance(&spec, 21).unwrap();
    assert_eq!(out.departures, 10);
    assert!(out.converged);
    // Reference: epoch 1's world (everyone active) solved independently.
    let topo = Topology::sample(&spec.base.system, 2, 10, 21);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let assoc = assoc::greedy(&channel, spec.base.system.edge_capacity()).unwrap();
    let inst = DelayInstance::build(&topo, &channel, &assoc, 0.25);
    let sol = solve_integer(&inst, &SolveOptions::default());
    let first_epoch_s = inst.round_time(sol.a as f64, sol.b as f64);
    assert!(
        rel_close(out.makespan_s, first_epoch_s, 1e-9),
        "makespan {} vs the one live round {first_epoch_s}",
        out.makespan_s
    );
}

#[test]
fn warm_resolve_reproduces_cold_trajectory() {
    // The acceptance cross-check: on a mobility+churn batch the warm
    // re-solve path must produce the same per-epoch (a*, b*) trajectory
    // and bitwise-identical makespans as solving cold every epoch (the
    // integer warm path is exactness-preserving by construction).
    for seed in [7u64, 21, 99] {
        let warm = run_instance(&dynamic_spec().resolve(ResolveMode::Warm), seed).unwrap();
        let cold = run_instance(&dynamic_spec().resolve(ResolveMode::Cold), seed).unwrap();
        assert_eq!(warm.ab_per_epoch, cold.ab_per_epoch, "seed {seed}");
        assert_eq!(warm.makespan_s.to_bits(), cold.makespan_s.to_bits());
        assert_eq!(warm.closed_form_s.to_bits(), cold.closed_form_s.to_bits());
        assert_eq!(warm.rounds, cold.rounds);
        assert_eq!(warm.epochs, cold.epochs);
        assert_eq!(warm.handovers, cold.handovers);
        // Warm mode only pays one seedless cold solve; cold mode pays one
        // per re-solve.
        assert!(warm.resolves > 1, "dynamic run must re-solve repeatedly");
        assert_eq!(warm.cold_resolves, 1);
        assert_eq!(cold.cold_resolves, cold.resolves);
    }
}

#[test]
fn toml_spec_end_to_end() {
    let spec = ScenarioSpec::parse_toml(
        r#"
[scenario]
num_edges = 2
num_ues = 12
eps = 0.25
seed = 4
assoc = "greedy"
[failure]
jitter_sigma = 0.05
[dynamics]
epoch_rounds = 1
max_epochs = 32
speed_min_mps = 0.5
speed_max_mps = 2.0
arrival_rate = 0.5
departure_prob = 0.02
[batch]
instances = 6
shards = 2
"#,
    )
    .unwrap();
    let batch = run_batch(&spec).unwrap();
    assert_eq!(batch.outcomes.len(), 6);
    let report = BatchReport::from_outcomes(&batch.outcomes);
    assert_eq!(report.instances, 6);
    assert!(report.makespan_s.mean > 0.0);
    assert!(report.makespan_s.p99 >= report.makespan_s.p50);
    // JSON report must round-trip through the in-tree parser.
    let text = report.to_json(Some(&spec)).to_string();
    assert!(hfl::util::json::Json::parse(&text).is_ok());
}

#[test]
fn fixed_iters_override_optimizer() {
    let spec = ScenarioSpec::new()
        .edges(2)
        .ues(10)
        .eps(0.25)
        .fixed_iters(13, 4);
    let out = run_instance(&spec, 8).unwrap();
    assert_eq!((out.a, out.b), (13, 4));
}

#[test]
fn tracing_does_not_perturb_outcomes() {
    // The acceptance contract of the trace subsystem: running with a live
    // JSONL sink yields bit-identical trajectories to running without one,
    // in both resolve modes.
    for resolve in [ResolveMode::Warm, ResolveMode::Cold] {
        let spec = dynamic_spec().resolve(resolve);
        let plain = run_instance(&spec, 77).unwrap();
        let mut sink = JsonlSink::new();
        let traced = run_instance_traced(&spec, 77, &mut sink).unwrap();
        assert_outcomes_bitwise_equal(
            std::slice::from_ref(&plain),
            std::slice::from_ref(&traced),
        );
        assert!(!sink.is_empty(), "a live sink must record events");
    }
}

#[test]
fn jsonl_content_is_seed_deterministic() {
    let spec = dynamic_spec();
    let mut a = JsonlSink::new();
    let mut b = JsonlSink::new();
    run_instance_traced(&spec, 42, &mut a).unwrap();
    run_instance_traced(&spec, 42, &mut b).unwrap();
    // wall_s fields are measured; everything else must reproduce exactly.
    assert_eq!(
        strip_walls(a.as_str()).unwrap(),
        strip_walls(b.as_str()).unwrap(),
        "same seed must produce identical trace content"
    );
    let mut c = JsonlSink::new();
    run_instance_traced(&spec, 43, &mut c).unwrap();
    assert_ne!(
        strip_walls(a.as_str()).unwrap(),
        strip_walls(c.as_str()).unwrap(),
        "different seeds must diverge"
    );
}

#[test]
fn traced_batch_is_shard_count_independent() {
    let spec = dynamic_spec().instances(6);
    let (one, sinks_one) = run_batch_traced(&spec.clone().shards(1), |_, _| {}).unwrap();
    let (four, sinks_four) = run_batch_traced(&spec.clone().shards(4), |_, _| {}).unwrap();
    assert_outcomes_bitwise_equal(&one.outcomes, &four.outcomes);
    let concat = |sinks: &[JsonlSink]| {
        let mut s = String::new();
        for sink in sinks {
            s.push_str(sink.as_str());
        }
        strip_walls(&s).unwrap()
    };
    assert_eq!(
        concat(&sinks_one),
        concat(&sinks_four),
        "concatenated trace content must not depend on shard count"
    );
}

/// A sink that counts every call it receives — used to prove the
/// disabled path never crosses the sink boundary.
struct CountingSink {
    on: bool,
    calls: u64,
}

impl TraceSink for CountingSink {
    fn enabled(&self) -> bool {
        self.on
    }
    fn instance(&mut self, _seed: u64) {
        self.calls += 1;
    }
    fn begin_epoch(&mut self, _epoch: u64, _clock_s: f64) {
        self.calls += 1;
    }
    fn counter(&mut self, _c: Counter, _v: u64) {
        self.calls += 1;
    }
    fn span(&mut self, _epoch: u64, _phase: Phase, _wall_s: f64) {
        self.calls += 1;
    }
    fn rounds(&mut self, _epoch: u64, _end_s: &[f64]) {
        self.calls += 1;
    }
}

#[test]
fn disabled_sink_receives_no_events() {
    let spec = dynamic_spec();
    let mut off = CountingSink { on: false, calls: 0 };
    run_instance_traced(&spec, 5, &mut off).unwrap();
    assert_eq!(off.calls, 0, "a disabled sink must receive zero calls");
    let mut on = CountingSink { on: true, calls: 0 };
    run_instance_traced(&spec, 5, &mut on).unwrap();
    assert!(on.calls > 0, "an enabled sink must receive the stream");
}

#[test]
fn phase_counters_cross_check_outcome_bookkeeping() {
    let spec = dynamic_spec();
    let mut sink = StatsSink::default();
    let out = run_instance_traced(&spec, 13, &mut sink).unwrap();
    // The sink saw exactly what the outcome accumulated.
    assert_eq!(sink.stats.counters, out.phase.counters);
    // The final epoch begins, discovers convergence, and breaks without
    // completing — begun = completed + 1.
    assert_eq!(sink.epochs, out.epochs + 1);
    // Counters agree with the outcome's own bookkeeping.
    assert_eq!(out.phase.count(Counter::ColdResolves), out.cold_resolves);
    assert_eq!(
        out.phase.count(Counter::WarmResolves) + out.phase.count(Counter::ColdResolves),
        out.resolves
    );
    assert_eq!(out.phase.count(Counter::SimRounds), out.rounds);
    // Derived timing: the legacy columns are the phase spans.
    assert_eq!(
        out.assoc_time_s.to_bits(),
        out.phase.wall(Phase::Assoc).to_bits()
    );
    assert_eq!(
        out.resolve_time_s.to_bits(),
        (out.phase.wall(Phase::Delay) + out.phase.wall(Phase::Resolve)).to_bits()
    );
}

#[test]
fn trace_profile_parses_engine_output() {
    let spec = dynamic_spec();
    let mut sink = JsonlSink::new();
    let out = run_instance_traced(&spec, 3, &mut sink).unwrap();
    let profile = TraceProfile::parse_jsonl(sink.as_str()).unwrap();
    assert_eq!(profile.instances, 1);
    // Epoch records count begun epochs (completed + the final partial one).
    assert_eq!(profile.epochs, out.epochs + 1);
    assert_eq!(profile.counter_total(Counter::SimRounds), out.rounds);
    assert!(profile.spans > 0);
    // Garbage is rejected, not mis-parsed.
    assert!(TraceProfile::parse_jsonl("not json\n").is_err());
}

#[test]
fn instance_seeds_vary_topology_but_share_spec() {
    let spec = ScenarioSpec::new().edges(2).ues(15).instances(4).shards(1);
    let batch = run_batch(&spec).unwrap();
    let mut makespans: Vec<u64> = batch
        .outcomes
        .iter()
        .map(|o| o.makespan_s.to_bits())
        .collect();
    makespans.sort_unstable();
    makespans.dedup();
    assert!(
        makespans.len() > 1,
        "different instance seeds must sample different topologies"
    );
}
