//! Quickstart: the whole stack in ~80 lines.
//!
//! 1. Sample a wireless deployment (paper §V-A defaults).
//! 2. Solve sub-problem II (Algorithm 3 association).
//! 3. Solve sub-problem I (optimal a*, b*).
//! 4. Simulate the protocol's latency.
//! 5. Run two cloud rounds of real hierarchical FL through PJRT.
//!
//! Run with:  cargo run --release --example quickstart

use hfl::assoc;
use hfl::coordinator::run_hfl;
use hfl::data::{partition_iid, synthetic};
use hfl::delay::DelayInstance;
use hfl::fl::{LocalSolver, TrainRun};
use hfl::net::{Channel, SystemParams, Topology};
use hfl::opt::{solve_integer, SolveOptions};
use hfl::runtime::{find_artifacts, Engine};
use hfl::sim::{simulate, SimConfig};
use hfl::util::Rng;

fn main() -> anyhow::Result<()> {
    // --- 1. Deployment: 3 edge servers, 30 UEs in a 500m x 500m square.
    let params = SystemParams::default();
    let topo = Topology::sample(&params, 3, 30, 42);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    println!("deployment: {} UEs, {} edges, capacity {}/edge",
        topo.num_ues(), topo.num_edges(), params.edge_capacity());

    // --- 2. Sub-problem II: time-minimized UE-to-edge association.
    let association = assoc::time_minimized(&channel, params.edge_capacity())
        .map_err(anyhow::Error::msg)?;
    println!("association loads: {:?}", association.load());

    // --- 3. Sub-problem I: optimal iteration counts for ε = 0.25.
    let inst = DelayInstance::build(&topo, &channel, &association, 0.25);
    let sol = solve_integer(&inst, &SolveOptions::default());
    println!("optimal a*={} b*={} -> {} cloud rounds, {:.3}s/round, {:.3}s total",
        sol.a, sol.b, sol.rounds, sol.round_time, sol.objective);

    // --- 4. Event-driven protocol simulation (sanity vs closed form).
    let sim = simulate(&inst, &SimConfig::deterministic(sol.a, sol.b));
    println!("simulated makespan {:.3}s over {} events", sim.total_time_s, sim.events);

    // --- 5. Two cloud rounds of REAL training through the PJRT runtime.
    let artifacts = find_artifacts(None)?;
    let engine = Engine::load(&artifacts)?;
    let gen = synthetic::SyntheticConfig::default();
    let corpus = synthetic::generate_split(&gen, 30 * 64, 42, 7);
    let test = synthetic::generate_split(&gen, 256, 42, 8);
    let shards = partition_iid(&corpus, 30, 64, &mut Rng::new(9)).map_err(anyhow::Error::msg)?;
    let run = TrainRun {
        a: 4, // short demo values; `hfl train` uses (a*, b*)
        b: 2,
        cloud_rounds: 2,
        round_time_s: inst.round_time(4.0, 2.0),
        eval_every: 1,
    };
    let outcome = run_hfl(
        &engine,
        LocalSolver::Gd { lr: 0.08 },
        shards,
        association.members(),
        &test,
        &run,
        0,
        42,
    )?;
    for p in &outcome.curve.points {
        println!("cloud round {}: sim time {:>7.2}s  test acc {:.3}  loss {:.3}",
            p.cloud_round, p.sim_time_s, p.test_acc, p.test_loss);
    }
    println!("quickstart OK (wall {:.1}s)", outcome.wall_s);
    Ok(())
}
