//! Extension study (beyond the paper): what the time-optimal schedule
//! COSTS. The paper pins f_n = f_max / p_n = p_max because its objective
//! is pure time (§IV-C.1); this driver sweeps CPU-frequency scaling and
//! prints the per-cloud-round (time, energy) Pareto frontier at the
//! optimizer's (a*, b*), using the standard κ·f²·cycles CMOS model.
//!
//!   cargo run --release --example energy_frontier

use hfl::assoc;
use hfl::delay::energy::{energy_time_frontier, KAPPA_DEFAULT};
use hfl::delay::DelayInstance;
use hfl::metrics::Recorder;
use hfl::net::{Channel, SystemParams, Topology};
use hfl::opt::{solve_integer, SolveOptions};

fn main() -> anyhow::Result<()> {
    let params = SystemParams::default();
    let topo = Topology::sample(&params, 5, 100, 42);
    let channel = Channel::compute(&params, &topo.ues, &topo.edges);
    let association =
        assoc::time_minimized(&channel, params.edge_capacity()).map_err(anyhow::Error::msg)?;
    let inst = DelayInstance::build(&topo, &channel, &association, 0.25);
    let sol = solve_integer(&inst, &SolveOptions::default());
    println!(
        "time-optimal schedule: a*={} b*={} (R={}, J={:.2}s at f_max)",
        sol.a, sol.b, sol.rounds, sol.objective
    );

    let scales: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
    let pts = energy_time_frontier(
        &topo,
        &channel,
        &association.members(),
        sol.a as f64,
        sol.b as f64,
        KAPPA_DEFAULT,
        &scales,
    );

    let mut rec = Recorder::new();
    let series = rec.series(
        "energy_frontier",
        &["f_scale", "round_time_s", "round_energy_j", "total_time_s", "total_energy_j"],
    );
    for p in &pts {
        series.push(vec![
            p.f_scale,
            p.round_time_s,
            p.round_energy_j,
            sol.rounds as f64 * p.round_time_s,
            sol.rounds as f64 * p.round_energy_j,
        ]);
    }
    series.print("per-round (time, energy) frontier vs CPU frequency scale");
    println!(
        "\nf_max is {:.1}x faster but {:.1}x more energy-hungry than f_max/2 —\nthe cost the paper's time-only objective implicitly accepts.",
        pts[4].round_time_s / pts[9].round_time_s,
        pts[9].round_energy_j / pts[4].round_energy_j
    );
    rec.write_dir(std::path::Path::new("results"))?;
    println!("wrote results/energy_frontier.csv");
    Ok(())
}
