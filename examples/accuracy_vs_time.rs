//! Figs. 4 & 6 driver — AND the repository's end-to-end validation run:
//! real hierarchical FL training (LeNet through the PJRT runtime) for a
//! grid of (a, b) iteration counts, reporting test accuracy against the
//! *simulated* protocol completion time from the delay model.
//!
//!   cargo run --release --example accuracy_vs_time -- --ues-per-edge 10   # Fig. 4
//!   cargo run --release --example accuracy_vs_time -- --ues-per-edge 20   # Fig. 6
//!
//! Options: --edges N (default 2), --cloud-rounds N (default 6),
//!          --samples-per-ue N (default 128), --pairs "35x5,30x7,20x10"
//!
//! Writes results/fig<4|6>_acc_vs_time_a<A>_b<B>.csv per pair; the run is
//! recorded in EXPERIMENTS.md.

use hfl::assoc;
use hfl::config::Args;
use hfl::coordinator::run_hfl;
use hfl::data::{partition_iid, synthetic};
use hfl::delay::DelayInstance;
use hfl::fl::{LocalSolver, TrainRun};
use hfl::metrics::Recorder;
use hfl::net::{Channel, SystemParams, Topology};
use hfl::runtime::{find_artifacts, Engine};
use hfl::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let upe = args.get_or("ues-per-edge", 10usize).map_err(anyhow::Error::msg)?;
    let edges = args.get_or("edges", 2usize).map_err(anyhow::Error::msg)?;
    let rounds = args.get_or("cloud-rounds", 6u64).map_err(anyhow::Error::msg)?;
    let spu = args.get_or("samples-per-ue", 128usize).map_err(anyhow::Error::msg)?;
    let lr = args.get_or("lr", 0.08f32).map_err(anyhow::Error::msg)?;
    let seed = args.get_or("seed", 42u64).map_err(anyhow::Error::msg)?;
    let pairs_s = args
        .str("pairs")
        .unwrap_or_else(|| "35x5,30x7,20x10,10x5".into());
    let pairs: Vec<(u64, u64)> = pairs_s
        .split(',')
        .map(|p| {
            let (a, b) = p.split_once('x').expect("pairs like 35x5");
            (a.parse().unwrap(), b.parse().unwrap())
        })
        .collect();

    let num_ues = edges * upe;
    let fig = if upe >= 20 { 6 } else { 4 };

    // Deployment + delay model (drives the x-axis).
    let params = SystemParams::default();
    let topo = Topology::sample(&params, edges, num_ues, seed);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let association =
        assoc::time_minimized(&channel, params.edge_capacity()).map_err(anyhow::Error::msg)?;
    let inst = DelayInstance::build(&topo, &channel, &association, 0.25);

    // Runtime + data.
    let engine = Engine::load(&find_artifacts(None)?)?;
    let gen = synthetic::SyntheticConfig::default();
    let corpus = synthetic::generate_split(&gen, num_ues * spu, seed, seed ^ 0xDA7A);
    let test = synthetic::generate_split(&gen, 1024, seed, seed ^ 0x7E57);
    let shards =
        partition_iid(&corpus, num_ues, spu, &mut Rng::new(seed ^ 0x5EED)).map_err(anyhow::Error::msg)?;

    println!(
        "Fig. {fig} run: {edges} edges x {upe} UEs, {rounds} cloud rounds, pairs {pairs:?}"
    );
    let mut rec = Recorder::new();
    for &(a, b) in &pairs {
        let run = TrainRun {
            a,
            b,
            cloud_rounds: rounds,
            round_time_s: inst.round_time(a as f64, b as f64),
            eval_every: 1,
        };
        let outcome = run_hfl(
            &engine,
            LocalSolver::Gd { lr },
            shards.clone(),
            association.members(),
            &test,
            &run,
            0,
            seed,
        )?;
        let name = format!("fig{fig}_acc_vs_time_a{a}_b{b}");
        let series = outcome.curve.to_series();
        series.print(&format!("(a={a}, b={b})  T={:.2}s/round", run.round_time_s));
        rec.series.insert(name, series);
        println!(
            "  -> final acc {:.4}, time-to-60% {:?}s, wall {:.1}s",
            outcome.curve.final_acc(),
            outcome.curve.time_to_accuracy(0.6),
            outcome.wall_s
        );
    }
    rec.write_dir(std::path::Path::new("results"))?;
    println!("\nwrote results/fig{fig}_acc_vs_time_*.csv");
    Ok(())
}
