//! Extension study (beyond the paper): statistical heterogeneity and the
//! local-solver choice. The paper trains on IID shards with plain GD;
//! this driver compares
//!
//!   IID + GD   vs   Dirichlet(α) non-IID + GD   vs   non-IID + DANE
//!
//! at the optimizer's (a*, b*), showing how label skew slows hierarchical
//! FedAvg and how much DANE's gradient correction recovers — the
//! systems-level question the paper's Future Work gestures at.
//!
//!   cargo run --release --example noniid_study -- --alpha 0.2 --cloud-rounds 3

use hfl::assoc;
use hfl::config::Args;
use hfl::coordinator::run_hfl;
use hfl::data::partition::label_skew;
use hfl::data::{partition_dirichlet, partition_iid, synthetic};
use hfl::delay::DelayInstance;
use hfl::fl::{LocalSolver, TrainRun};
use hfl::metrics::Recorder;
use hfl::net::{Channel, SystemParams, Topology};
use hfl::runtime::{find_artifacts, Engine};
use hfl::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let alpha = args.get_or("alpha", 0.2f64).map_err(anyhow::Error::msg)?;
    let rounds = args.get_or("cloud-rounds", 3u64).map_err(anyhow::Error::msg)?;
    let spu = args.get_or("samples-per-ue", 96usize).map_err(anyhow::Error::msg)?;
    let seed = args.get_or("seed", 42u64).map_err(anyhow::Error::msg)?;
    let (edges, ues) = (2usize, 8usize);

    let params = SystemParams::default();
    let topo = Topology::sample(&params, edges, ues, seed);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let association =
        assoc::time_minimized(&channel, params.edge_capacity()).map_err(anyhow::Error::msg)?;
    let inst = DelayInstance::build(&topo, &channel, &association, 0.25);

    let engine = Engine::load(&find_artifacts(None)?)?;
    let gen = synthetic::SyntheticConfig::default();
    let corpus = synthetic::generate_split(&gen, ues * spu, seed, seed ^ 0xDA7A);
    let test = synthetic::generate_split(&gen, 512, seed, seed ^ 0x7E57);

    let (a, b) = (8u64, 2u64);
    let run = TrainRun {
        a,
        b,
        cloud_rounds: rounds,
        round_time_s: inst.round_time(a as f64, b as f64),
        eval_every: 1,
    };

    let mut rec = Recorder::new();
    let mut summary = hfl::metrics::Series::new(&["case", "label_skew", "final_acc", "final_loss"]);

    let cases: Vec<(&str, f64, LocalSolver)> = vec![
        ("iid_gd", 0.0, LocalSolver::Gd { lr: 0.08 }),
        ("noniid_gd", alpha, LocalSolver::Gd { lr: 0.08 }),
        ("noniid_dane", alpha, LocalSolver::Dane { lr: 0.08 }),
    ];
    for (idx, (name, a_dir, solver)) in cases.into_iter().enumerate() {
        let mut rng = Rng::new(seed ^ 0x5EED);
        let shards = if a_dir > 0.0 {
            partition_dirichlet(&corpus, ues, spu, a_dir, &mut rng)
        } else {
            partition_iid(&corpus, ues, spu, &mut rng)
        }
        .map_err(anyhow::Error::msg)?;
        let skew = label_skew(&shards);
        let outcome = run_hfl(
            &engine,
            solver,
            shards,
            association.members(),
            &test,
            &run,
            1,
            seed,
        )?;
        let last = outcome.curve.points.last().unwrap();
        println!(
            "{name:<12} skew {skew:.3}  final acc {:.4}  loss {:.4}  (wall {:.0}s)",
            last.test_acc, last.test_loss, outcome.wall_s
        );
        summary.push(vec![idx as f64, skew, last.test_acc as f64, last.test_loss as f64]);
        rec.series
            .insert(format!("noniid_curve_{name}"), outcome.curve.to_series());
    }
    rec.series.insert("noniid_summary".into(), summary);
    rec.write_dir(std::path::Path::new("results"))?;
    println!("wrote results/noniid_*.csv");
    Ok(())
}
