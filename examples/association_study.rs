//! Fig. 5 driver: maximum system latency of 100 UEs under different
//! numbers of edge servers, for the proposed (Algorithm 3), greedy and
//! random association strategies — plus the exact matching optimum the
//! paper does not compute.
//!
//!   cargo run --release --example association_study
//!
//! Writes results/fig5_association.csv.

use hfl::assoc::{self, LatencyTable};
use hfl::config::Args;
use hfl::delay::DelayInstance;
use hfl::metrics::Recorder;
use hfl::net::{Channel, SystemParams, Topology};
use hfl::opt::{solve_integer, SolveOptions};
use hfl::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let num_ues = args.get_or("ues", 100usize).map_err(anyhow::Error::msg)?;
    let eps = args.get_or("eps", 0.25f64).map_err(anyhow::Error::msg)?;
    let seed = args.get_or("seed", 42u64).map_err(anyhow::Error::msg)?;
    let trials = args.get_or("trials", 5usize).map_err(anyhow::Error::msg)?;

    let mut rec = Recorder::new();
    let series = rec.series(
        "fig5_association",
        &["edges", "proposed_s", "greedy_s", "random_s", "exact_s"],
    );

    for edges in [6usize, 7, 8, 9, 10, 12, 14, 16] {
        let (mut p_acc, mut g_acc, mut r_acc, mut e_acc) = (0.0, 0.0, 0.0, 0.0);
        for t in 0..trials {
            let params = SystemParams::default();
            let topo = Topology::sample(&params, edges, num_ues, seed + t as u64 * 1000);
            let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
            let cap = params.edge_capacity();

            // a* from sub-problem I under a provisional association.
            let prov = assoc::greedy(&channel, cap).map_err(anyhow::Error::msg)?;
            let inst = DelayInstance::build(&topo, &channel, &prov, eps);
            let a = solve_integer(&inst, &SolveOptions::default()).a;
            let table = LatencyTable::build(&topo, &channel, a as f64);

            let proposed = assoc::time_minimized(&channel, cap).map_err(anyhow::Error::msg)?;
            let greedy = assoc::greedy(&channel, cap).map_err(anyhow::Error::msg)?;
            let random = assoc::random(num_ues, edges, cap, &mut Rng::new(seed + t as u64))
                .map_err(anyhow::Error::msg)?;
            let exact = assoc::solve_exact_matching(&table, cap).map_err(anyhow::Error::msg)?;

            p_acc += table.max_latency(&proposed);
            g_acc += table.max_latency(&greedy);
            r_acc += table.max_latency(&random);
            e_acc += table.max_latency(&exact);
        }
        let k = trials as f64;
        series.push(vec![edges as f64, p_acc / k, g_acc / k, r_acc / k, e_acc / k]);
    }
    series.print(&format!(
        "Fig. 5 — max latency of {num_ues} UEs vs #edge servers (mean of {trials} seeds)"
    ));
    rec.write_dir(std::path::Path::new("results"))?;
    println!("\nwrote results/fig5_association.csv");
    Ok(())
}
