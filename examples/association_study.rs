//! Fig. 5 driver — ported to the declarative scenario engine: maximum
//! per-edge-round latency of `--ues` UEs under different numbers of edge
//! servers, for the proposed (Algorithm 3), greedy, random and exact
//! (matching) association strategies.
//!
//!   cargo run --release --example association_study [-- --ues N --eps E
//!     --seed S --trials T]
//!
//! Each (edges, strategy) cell is one [`ScenarioSpec`] batch of `trials`
//! instances on the fleet runner; all cells share the batch seed, so
//! every strategy is scored on identical topologies. The reported metric
//! is the batch-mean `max_m τ_m(a*)` — the paper's Fig. 5 min-max
//! association objective, evaluated at each strategy's own solved a*
//! (the seed version fixed a common provisional a; see EXPERIMENTS.md
//! §Fig5 for the comparison note). Writes results/fig5_association.csv.
//!
//! Part 2 re-runs a small mobility+churn batch under both
//! `assoc_resolve` modes (warm incremental engine vs cold per-epoch
//! policy runs) and prints the agreement check, so the example doubles
//! as a manual warm==cold verification tool.

use hfl::config::{Args, AssocStrategy};
use hfl::metrics::Recorder;
use hfl::scenario::{ResolveMode, ScenarioRun, ScenarioSpec};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let num_ues = args.get_or("ues", 100usize).map_err(anyhow::Error::msg)?;
    let eps = args.get_or("eps", 0.25f64).map_err(anyhow::Error::msg)?;
    let seed = args.get_or("seed", 42u64).map_err(anyhow::Error::msg)?;
    let trials = args.get_or("trials", 5usize).map_err(anyhow::Error::msg)?;

    let strategies = [
        AssocStrategy::Proposed,
        AssocStrategy::Greedy,
        AssocStrategy::Random,
        AssocStrategy::Exact,
    ];

    let mut rec = Recorder::new();
    let series = rec.series(
        "fig5_association",
        &["edges", "proposed_s", "greedy_s", "random_s", "exact_s"],
    );

    for edges in [6usize, 7, 8, 9, 10, 12, 14, 16] {
        let mut row = vec![edges as f64];
        for strategy in strategies {
            let spec = ScenarioSpec::new()
                .edges(edges)
                .ues(num_ues)
                .eps(eps)
                .seed(seed)
                .assoc(strategy)
                .instances(trials);
            let batch = ScenarioRun::new(&spec).run_batch().map_err(anyhow::Error::msg)?;
            let mean_tau = batch
                .outcomes
                .iter()
                .map(|o| o.tau_max_s)
                .sum::<f64>()
                / trials as f64;
            row.push(mean_tau);
        }
        series.push(row);
    }
    series.print(&format!(
        "Fig. 5 — max edge-round latency of {num_ues} UEs vs #edge servers (mean of {trials} instances)"
    ));
    rec.write_dir(std::path::Path::new("results"))?;
    println!("\nwrote results/fig5_association.csv");

    // Part 2 — assoc_resolve agreement: the incremental engine must hand
    // the epoch loop maps bitwise-identical to cold policy re-runs.
    println!("\nassoc_resolve warm/cold agreement (5 edges, mobility + churn, proposed):");
    let dynamic = |mode: ResolveMode| {
        ScenarioSpec::new()
            .edges(5)
            .ues(num_ues)
            .eps(eps)
            .seed(seed)
            .mobility(0.5, 2.0)
            .churn(1.0, 0.02)
            .epoch_rounds(1)
            .max_epochs(24)
            .instances(trials)
            .shards(1)
            .assoc_resolve(mode)
    };
    let warm_spec = dynamic(ResolveMode::Warm);
    let cold_spec = dynamic(ResolveMode::Cold);
    let warm = ScenarioRun::new(&warm_spec).run_batch().map_err(anyhow::Error::msg)?;
    let cold = ScenarioRun::new(&cold_spec).run_batch().map_err(anyhow::Error::msg)?;
    let mut agree = true;
    for (w, c) in warm.outcomes.iter().zip(&cold.outcomes) {
        if w.ab_per_epoch != c.ab_per_epoch
            || w.makespan_s.to_bits() != c.makespan_s.to_bits()
            || w.handovers != c.handovers
        {
            agree = false;
        }
    }
    let (mut wt, mut ct, mut wr, mut cr) = (0.0f64, 0.0f64, 0u64, 0u64);
    for w in &warm.outcomes {
        wt += w.assoc_time_s;
        wr += w.reassociations;
    }
    for c in &cold.outcomes {
        ct += c.assoc_time_s;
        cr += c.reassociations;
    }
    println!(
        "  warm: {:.3} ms assoc time, {wr} reprocessed UEs | cold: {:.3} ms, {cr}",
        wt * 1e3,
        ct * 1e3
    );
    println!(
        "  (a,b) trajectories + makespans + handovers: {}",
        if agree { "OK — warm == cold" } else { "MISMATCH" }
    );
    if !agree {
        anyhow::bail!("assoc_resolve warm diverged from cold");
    }
    Ok(())
}
