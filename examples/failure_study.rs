//! Extension study (beyond the paper): protocol robustness under
//! stragglers and dropouts, using the event-driven simulator.
//!
//!   cargo run --release --example failure_study
//!
//! Sweeps lognormal jitter σ and per-round dropout probability and
//! reports the makespan inflation / deflation relative to the
//! deterministic closed form — quantifying how fragile the paper's
//! deterministic delay model is to real-world noise.

use hfl::assoc;
use hfl::delay::DelayInstance;
use hfl::metrics::Recorder;
use hfl::net::{Channel, SystemParams, Topology};
use hfl::opt::{solve_integer, SolveOptions};
use hfl::sim::{simulate, SimConfig};

fn main() -> anyhow::Result<()> {
    let params = SystemParams::default();
    let topo = Topology::sample(&params, 5, 100, 42);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let association =
        assoc::time_minimized(&channel, params.edge_capacity()).map_err(anyhow::Error::msg)?;
    let inst = DelayInstance::build(&topo, &channel, &association, 0.25);
    let sol = solve_integer(&inst, &SolveOptions::default());
    let base = inst.total_time_int(sol.a as f64, sol.b as f64);
    println!("baseline: a*={} b*={} deterministic makespan {base:.2}s", sol.a, sol.b);

    let mut rec = Recorder::new();
    let js = rec.series("jitter_sweep", &["sigma", "makespan_s", "inflation", "ue_wait_s"]);
    for &sigma in &[0.0, 0.05, 0.1, 0.2, 0.4, 0.8] {
        let mut acc = 0.0;
        let mut wait = 0.0;
        let trials = 10;
        for t in 0..trials {
            let cfg = SimConfig {
                jitter_sigma: sigma,
                seed: 1000 + t,
                ..SimConfig::deterministic(sol.a, sol.b)
            };
            let r = simulate(&inst, &cfg);
            acc += r.total_time_s;
            wait += r.ue_barrier_wait_s;
        }
        let mk = acc / trials as f64;
        js.push(vec![sigma, mk, mk / base, wait / trials as f64]);
    }
    js.print("makespan vs straggler jitter σ (mean of 10 seeds)");

    let ds = rec.series("dropout_sweep", &["dropout", "makespan_s", "dropped", "speedup"]);
    for &p in &[0.0, 0.01, 0.05, 0.1, 0.2, 0.5] {
        let mut acc = 0.0;
        let mut dropped = 0.0;
        let trials = 10;
        for t in 0..trials {
            let cfg = SimConfig {
                dropout_prob: p,
                seed: 2000 + t,
                ..SimConfig::deterministic(sol.a, sol.b)
            };
            let r = simulate(&inst, &cfg);
            acc += r.total_time_s;
            dropped += r.dropped_uploads as f64;
        }
        let mk = acc / trials as f64;
        ds.push(vec![p, mk, dropped / trials as f64, base / mk]);
    }
    ds.print("makespan vs UE dropout probability (mean of 10 seeds)");

    rec.write_dir(std::path::Path::new("results"))?;
    println!("\nwrote results/jitter_sweep.csv, results/dropout_sweep.csv");
    Ok(())
}
