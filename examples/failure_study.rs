//! Extension study (beyond the paper): protocol robustness under
//! stragglers and dropouts — ported to the declarative scenario engine.
//!
//!   cargo run --release --example failure_study
//!
//! Each (σ, p) grid point is one [`ScenarioSpec`] batch fanned out over
//! the parallel fleet runner: `trials` instances per point, every
//! instance an independently sampled topology + noise stream with a
//! seed derived from the shared batch seed (so every sweep point sees
//! the *same* topologies and only the failure model varies). Reported
//! makespans are batch means; the inflation baseline is the zero-noise
//! closed form `⌈R⌉ · T(a*, b*)` from the same batch.

use hfl::metrics::Recorder;
use hfl::scenario::{ScenarioRun, ScenarioSpec};
use hfl::util::stats;

/// Batch-mean of one outcome metric.
fn mean<F: Fn(&hfl::scenario::ScenarioOutcome) -> f64>(
    batch: &hfl::scenario::BatchResult,
    f: F,
) -> f64 {
    let xs: Vec<f64> = batch.outcomes.iter().map(f).collect();
    stats::mean(&xs)
}

fn main() -> anyhow::Result<()> {
    let trials = 10;
    let base = ScenarioSpec::new()
        .edges(5)
        .ues(100)
        .eps(0.25)
        .seed(42)
        .instances(trials);

    // Zero-noise reference batch: simulated == closed form per instance.
    let reference = ScenarioRun::new(&base).run_batch().map_err(anyhow::Error::msg)?;
    let base_mean = mean(&reference, |o| o.closed_form_s);
    println!(
        "baseline: deterministic makespan {base_mean:.2}s (mean of {trials} topologies; \
         instance 0 solved a*={} b*={})",
        reference.outcomes[0].a, reference.outcomes[0].b
    );

    let mut rec = Recorder::new();
    let js = rec.series("jitter_sweep", &["sigma", "makespan_s", "inflation", "ue_wait_s"]);
    for &sigma in &[0.0, 0.05, 0.1, 0.2, 0.4, 0.8] {
        let spec = base.clone().jitter(sigma);
        let batch = ScenarioRun::new(&spec).run_batch().map_err(anyhow::Error::msg)?;
        let mk = mean(&batch, |o| o.makespan_s);
        let wait = mean(&batch, |o| o.ue_barrier_wait_s);
        js.push(vec![sigma, mk, mk / base_mean, wait]);
    }
    js.print(&format!(
        "makespan vs straggler jitter σ (mean of {trials} instances)"
    ));

    let ds = rec.series("dropout_sweep", &["dropout", "makespan_s", "dropped", "speedup"]);
    for &p in &[0.0, 0.01, 0.05, 0.1, 0.2, 0.5] {
        let spec = base.clone().dropout(p);
        let batch = ScenarioRun::new(&spec).run_batch().map_err(anyhow::Error::msg)?;
        let mk = mean(&batch, |o| o.makespan_s);
        let dropped = mean(&batch, |o| o.dropped_uploads as f64);
        ds.push(vec![p, mk, dropped, base_mean / mk]);
    }
    ds.print(&format!(
        "makespan vs UE dropout probability (mean of {trials} instances)"
    ));

    rec.write_dir(std::path::Path::new("results"))?;
    println!("\nwrote results/jitter_sweep.csv, results/dropout_sweep.csv");
    Ok(())
}
