//! Figs. 2 & 3 driver: optimal iteration counts (a*, b*) as the target
//! global accuracy ε and the per-edge UE count vary.
//!
//!   cargo run --release --example sweep_accuracy            # Fig. 2 sweep
//!   cargo run --release --example sweep_accuracy -- --sweep ues   # Fig. 3
//!
//! Writes results/fig2_*.csv / results/fig3_*.csv.

use hfl::assoc;
use hfl::config::Args;
use hfl::delay::DelayInstance;
use hfl::metrics::Recorder;
use hfl::net::{Channel, SystemParams, Topology};
use hfl::opt::{solve_integer, SolveOptions, SubgradientSolver};

fn instance(edges: usize, ues_per_edge: usize, eps: f64, seed: u64) -> DelayInstance {
    let mut params = SystemParams::default();
    // Keep the bandwidth cap feasible for the large sweeps (Fig. 3 goes
    // to 100 UEs/edge; the default capacity is 20).
    params.ue_bandwidth_hz = params.edge_bandwidth_hz / ues_per_edge.max(20) as f64;
    let topo = Topology::sample(&params, edges, edges * ues_per_edge, seed);
    let channel = Channel::compute(&topo.params, &topo.ues, &topo.edges);
    let assoc = assoc::time_minimized(&channel, params.edge_capacity()).expect("feasible");
    DelayInstance::build(&topo, &channel, &assoc, eps)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let sweep = args.str("sweep").unwrap_or_else(|| "eps".into());
    let seed = args.get_or("seed", 42u64).map_err(anyhow::Error::msg)?;
    let mut rec = Recorder::new();
    let opts = SolveOptions::default();

    match sweep.as_str() {
        // ---- Fig. 2: 5 edges x 20 UEs, ε from 0.5 down to 0.05.
        "eps" => {
            let series = rec.series(
                "fig2_iters_vs_eps",
                &["eps", "a_star", "b_star", "a_times_b", "rounds", "total_s", "alg2_a", "alg2_b"],
            );
            for eps in [0.5, 0.4, 0.3, 0.25, 0.2, 0.15, 0.1, 0.05] {
                let inst = instance(5, 20, eps, seed);
                let sol = solve_integer(&inst, &opts);
                let alg2 = SubgradientSolver::default().solve(&inst);
                series.push(vec![
                    eps,
                    sol.a as f64,
                    sol.b as f64,
                    (sol.a * sol.b) as f64,
                    sol.rounds as f64,
                    sol.objective,
                    alg2.a.round(),
                    alg2.b.round(),
                ]);
            }
            series.print("Fig. 2 — optimal iterations vs global accuracy ε");
        }
        // ---- Fig. 3: ε = 0.25, UEs per edge from 10 to 100.
        "ues" => {
            let series = rec.series(
                "fig3_iters_vs_ues",
                &["ues_per_edge", "a_star", "b_star", "rounds", "total_s"],
            );
            for upe in [10usize, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
                // A fresh topology per point: the paper redraws C_n/D_n, so
                // the series shows "no visible trend" — reproduce that.
                let inst = instance(5, upe, 0.25, seed + upe as u64);
                let sol = solve_integer(&inst, &opts);
                series.push(vec![
                    upe as f64,
                    sol.a as f64,
                    sol.b as f64,
                    sol.rounds as f64,
                    sol.objective,
                ]);
            }
            series.print("Fig. 3 — optimal iterations vs UEs per edge (ε = 0.25)");
        }
        other => anyhow::bail!("unknown --sweep '{other}' (eps|ues)"),
    }

    rec.write_dir(std::path::Path::new("results"))?;
    println!("\nwrote results/ CSVs");
    Ok(())
}
